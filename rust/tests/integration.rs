//! Cross-module integration tests: the coordinator serving a mixed trace
//! (all engines), generated-code backends on every built-in app, and the
//! schedule/DOT inspection surfaces the CLI exposes.

use hfav::apps::{deck_of, Variant};
use hfav::coordinator::{parse_trace_line, Coordinator, Job};
use hfav::plan::{PlanSpec, Program};

fn compile_variant(deck: &str, v: Variant) -> Result<Program, String> {
    PlanSpec::deck_src(deck).variant(v).compile()
}

#[test]
fn serve_sample_trace_exec_and_native() {
    // The repo's sample trace, minus PJRT (artifacts may not be built in
    // every test environment) and shrunk for test time.
    let trace = "\
laplace, hfav, native, 96, 2
laplace, autovec, exec, 48, 1
normalize, hfav, native, 96, 2
cosmo, hfav, exec, 24, 1
hydro2d, hfav, native, 24, 2
";
    let jobs: Vec<Job> = trace
        .lines()
        .enumerate()
        .map(|(i, l)| parse_trace_line(i as u64, l).unwrap())
        .collect();
    let c = Coordinator::start(3, None);
    let results = c.run_batch(jobs);
    for r in &results {
        assert!(r.ok, "job {}: {}", r.id, r.detail);
        assert!(r.checksum.is_finite());
    }
    let summary = c.metrics.summary();
    assert!(summary.contains("completed=5"), "{summary}");
    c.shutdown();
}

#[test]
fn pjrt_jobs_fail_gracefully_without_backend() {
    // No artifacts dir and no XLA toolchain in this build: a PJRT job must
    // come back as a clean per-job failure, never a worker panic, and must
    // not poison subsequent jobs on the same worker.
    let c = Coordinator::start(1, None);
    let r = c.submit(Job::new(0, PlanSpec::app("laplace"), "pjrt", 64, 1)).recv().unwrap();
    assert!(!r.ok);
    assert!(
        r.detail.contains("PJRT") || r.detail.contains("artifacts"),
        "unexpected detail: {}",
        r.detail
    );
    let r2 = c.submit(Job::new(1, PlanSpec::app("laplace"), "exec", 32, 1)).recv().unwrap();
    assert!(r2.ok, "worker poisoned by failed PJRT job: {}", r2.detail);
    c.shutdown();
}

#[test]
fn all_backends_emit_for_all_apps() {
    for app in ["laplace", "normalize", "cosmo", "hydro2d"] {
        let deck = deck_of(app).unwrap();
        for variant in [Variant::Hfav, Variant::Autovec] {
            let prog = compile_variant(deck, variant).unwrap();
            let c = hfav::codegen::c99::emit(&prog).unwrap();
            assert!(c.contains("hfav_run"), "{app} {variant:?}");
            let r = hfav::codegen::rs::emit(&prog).unwrap();
            assert!(r.contains("pub fn hfav_run"), "{app} {variant:?}");
            let d = hfav::codegen::dot::dataflow(&prog.df);
            assert!(d.starts_with("digraph"), "{app}");
            let i = hfav::codegen::dot::inest(&prog.df, &prog.fd);
            assert!(i.contains("cluster_0"), "{app}");
            assert!(!prog.schedule_text().is_empty());
        }
    }
}

#[test]
fn generated_c_for_all_apps_compiles() {
    // Every built-in deck's generated C must compile under cc -O3.
    for app in ["laplace", "normalize", "cosmo", "hydro2d"] {
        let deck = deck_of(app).unwrap();
        let prog = compile_variant(deck, Variant::Hfav).unwrap();
        let m = hfav::codegen::native::build(&prog, &Default::default())
            .unwrap_or_else(|e| panic!("{app}: {e}"));
        assert!(!m.externals.is_empty());
    }
}

#[test]
fn schedule_shows_hydro_pipeline_shift() {
    // The fused hydro nest must run `trace` one i-iteration ahead of the
    // interface kernels (software pipelining, paper §3.3).
    let prog = compile_variant(deck_of("hydro2d").unwrap(), Variant::Hfav).unwrap();
    assert_eq!(prog.fd.nests.len(), 1);
    let nest = &prog.fd.nests[0];
    let shift_of = |name: &str| {
        let cs = prog.df.callsites.iter().find(|c| c.name == name).unwrap();
        let m = nest.member(cs.id).unwrap();
        *m.shifts.last().unwrap()
    };
    assert!(shift_of("trace") >= 1, "trace shift {}", shift_of("trace"));
    assert!(shift_of("slope") >= shift_of("trace"));
    assert!(shift_of("constoprim") > shift_of("slope") || shift_of("constoprim") >= 2);
    assert_eq!(shift_of("update_cons_vars"), 0);
}

#[test]
fn footprint_accounting_matches_storage_sum() {
    use std::collections::BTreeMap;
    let prog = compile_variant(deck_of("cosmo").unwrap(), Variant::Hfav).unwrap();
    let mut ext = BTreeMap::new();
    ext.insert("Nk".to_string(), 4i64);
    ext.insert("Nj".to_string(), 64i64);
    ext.insert("Ni".to_string(), 64i64);
    let total = prog.footprint_words(&ext).unwrap();
    let sum: i64 = prog
        .sp
        .storages
        .iter()
        .filter(|s| s.external.is_none())
        .map(|s| hfav::analysis::storage_words(s, &prog.df, &ext).unwrap())
        .sum();
    assert_eq!(total, sum);
    assert!(total > 0);
}
