//! Tier-1 fuzz coverage: a bounded, deterministic seed range through the
//! two-stage fuzz driver, plus replay of every committed minimized
//! reproducer (`traces/fuzz-regress-*.yaml`) so fixed bugs stay fixed.
//!
//! The ranges here are deliberately small (seconds, not minutes) — the
//! CI `fuzz` job runs the wide campaign (`hfav fuzz --seeds 200 --seed
//! 0xC0FFEE`). Failures print the minimized reproducer decks so a red
//! run is immediately replayable.

use hfav::fuzz::{self, FuzzConfig, FuzzEngine};
use hfav::plan::{PlanSpec, Vlen};
use hfav::apps::Variant;

/// Panic with full minimized reproducers when a campaign isn't clean.
fn assert_clean(rep: &fuzz::FuzzReport, what: &str) {
    if rep.clean() {
        return;
    }
    let mut msg = format!("{what}:\n{}", rep.summary());
    for f in &rep.findings {
        msg.push_str(&format!(
            "--- seed 0x{:x} [{}] minimized reproducer ---\n{}",
            f.seed, f.knobs, f.deck
        ));
    }
    panic!("{msg}");
}

#[test]
fn stage1_clean_on_deterministic_seed_range() {
    let cfg = FuzzConfig {
        seeds: 32,
        seed0: 0,
        engines: Some(vec![FuzzEngine::Exec]),
        stage2: false,
        out_dir: None,
        verbose: false,
    };
    let rep = fuzz::run(&cfg).unwrap();
    assert_clean(&rep, "stage-1 fuzz (compile + verifier oracle)");
    assert_eq!(rep.seeds_run, 32);
    // Every seed's unfused scalar baseline must have compiled, plus at
    // least some fused plans.
    assert!(rep.plans_compiled >= 32, "baseline compiles missing: {}", rep.plans_compiled);
    assert!(rep.plans_verified > 0, "no fused plan survived to the verifier");
}

#[test]
fn stage2_differential_clean_on_interpreter() {
    let cfg = FuzzConfig {
        seeds: 10,
        seed0: 0,
        engines: Some(vec![FuzzEngine::Exec]),
        stage2: true,
        out_dir: None,
        verbose: false,
    };
    let rep = fuzz::run(&cfg).unwrap();
    assert_clean(&rep, "stage-2 fuzz differential (interpreter)");
    assert!(rep.diff_runs > 0, "differential stage never ran");
}

#[test]
fn stage2_differential_clean_on_native_c() {
    if !FuzzEngine::Native.available() {
        eprintln!("fuzz: no C compiler on PATH — native differential test skipped");
        return;
    }
    let cfg = FuzzConfig {
        seeds: 6,
        seed0: 0,
        engines: Some(vec![FuzzEngine::Native]),
        stage2: true,
        out_dir: None,
        verbose: false,
    };
    let rep = fuzz::run(&cfg).unwrap();
    assert_clean(&rep, "stage-2 fuzz differential (native C)");
    assert!(rep.diff_runs > 0);
}

#[test]
fn campaign_is_deterministic() {
    let cfg = FuzzConfig {
        seeds: 8,
        seed0: 0x51,
        engines: Some(vec![FuzzEngine::Exec]),
        stage2: false,
        out_dir: None,
        verbose: false,
    };
    let a = fuzz::run(&cfg).unwrap();
    let b = fuzz::run(&cfg).unwrap();
    assert_eq!(a.summary(), b.summary());
    assert_eq!(a.plans_compiled, b.plans_compiled);
    assert_eq!(a.legality_skips, b.legality_skips);
    assert_eq!(a.plans_verified, b.plans_verified);
}

/// Every committed minimized reproducer must replay clean: it pinned a
/// bug that has since been fixed, so compile + independent verification
/// must now succeed at the scalar corner (the header's exact knob line
/// is for manual replay via `hfav check`/`hfav fuzz`). An empty set of
/// reproducers — a clean campaign history — passes trivially.
#[test]
fn committed_reproducers_replay_clean() {
    let traces = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../traces");
    let mut replayed = 0usize;
    for entry in std::fs::read_dir(&traces).expect("traces dir") {
        let path = entry.unwrap().path();
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        if !(name.starts_with("fuzz-regress-") && name.ends_with(".yaml")) {
            continue;
        }
        let src = std::fs::read_to_string(&path).unwrap();
        for variant in [Variant::Hfav, Variant::Autovec] {
            let prog = PlanSpec::deck_src(src.as_str())
                .variant(variant)
                .vlen(Vlen::Fixed(1))
                .compile()
                .unwrap_or_else(|e| panic!("{name} ({variant:?}): does not compile: {e}"));
            let rep = hfav::verify::check_program(&prog)
                .unwrap_or_else(|e| panic!("{name} ({variant:?}): verifier refused: {e}"));
            assert!(
                !rep.has_errors(),
                "{name} ({variant:?}): verifier errors:\n{}",
                rep.render()
            );
        }
        replayed += 1;
    }
    eprintln!("fuzz: replayed {replayed} committed reproducer deck(s)");
}
