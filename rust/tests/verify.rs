//! Static-verifier integration suite.
//!
//! Two halves: the knob-matrix property test (every builtin app × knob
//! set × worker count must verify clean — the verifier agreeing with
//! every legality gate on every shipped schedule shape), and the
//! seeded-defect mutation tests (each verifier rule must actually fire
//! when a compiled schedule is corrupted the way a transformation bug
//! would corrupt it: a loop bound off by one, a dropped private replica,
//! a window one power-of-two too small, an invocation out of order).

use hfav::analysis::{DimSize, VecDim};
use hfav::plan::{PlanSpec, Program};
use hfav::schedule::Node;
use hfav::verify;
use std::collections::BTreeMap;

/// Inline copy of the 1D producer/consumer chain deck (the unit-test
/// fixture lives behind `cfg(test)` in the library and is not visible to
/// integration tests): d[i] = b[i+1]-b[i-1] where b = 2a, so the
/// producer runs ahead of the consumer through a rolling window.
const CHAIN1D: &str = r#"
name: chain1d
iteration:
  order: [i]
  domains:
    i: [1, N-1]
kernels:
  dbl:
    declaration: dbl(double a, double &b);
    inputs: |
      a : u?[i?]
    outputs: |
      b : dbl(u?[i?])
    body: "b = 2.0*a;"
  diff:
    declaration: diff(double l, double r, double &d);
    inputs: |
      l : dbl(u?[i?-1])
      r : dbl(u?[i?+1])
    outputs: |
      d : diff(u?[i?])
    body: "d = r - l;"
globals:
  inputs: |
    double g_u[i?] => u[i?]
  outputs: |
    diff(u[i]) => double g_d[i]
"#;

fn probe(prog: &Program) -> BTreeMap<String, i64> {
    verify::probe_extents(prog, 4)
}

/// The satellite knob matrix: {scalar, inner, outer, aligned, tiled}
/// (plus §5.3 tuning), each labelled for failure messages.
fn knob_specs(app: &str) -> Vec<(&'static str, PlanSpec)> {
    let base = PlanSpec::app(app);
    vec![
        ("scalar", base.clone().vlen_resolved(Some(1))),
        ("inner", base.clone().vlen_resolved(Some(4))),
        ("outer", base.clone().vlen_resolved(Some(4)).vec_dim(VecDim::Auto)),
        ("aligned", base.clone().vlen_resolved(Some(4)).aligned(true)),
        ("tiled", base.clone().vlen_resolved(Some(4)).tiled(true)),
        ("tuned", base.vlen_resolved(Some(4)).tuned(true)),
    ]
}

#[test]
fn knob_matrix_verifies_clean_on_every_builtin_app() {
    for app in hfav::apps::APP_NAMES {
        for (label, spec) in knob_specs(app) {
            let prog = match spec.compile() {
                Ok(p) => p,
                // Illegal knob corner for this deck (e.g. no legal
                // outer dim for tiling) — the legality gates filter it
                // before the verifier ever sees a schedule.
                Err(_) => continue,
            };
            let ext = probe(&prog);
            let report = verify::check_schedule_at(&prog, &ext, &[2]).unwrap();
            assert!(!report.has_errors(), "{app}/{label}:\n{}", report.render());
            assert!(
                verify::lint_deck(&prog)
                    .iter()
                    .all(|d| d.severity != verify::Severity::Error),
                "{app}/{label} has error-severity deck lints"
            );
        }
    }
}

#[test]
fn out_of_window_stencil_deck_fails_check() {
    // Acceptance case: widening laplace's domain so the j-1 read reaches
    // index -1 of the declared input must produce an error-severity
    // finding (the CLI turns this into a nonzero exit).
    let bad = r#"
name: bad_laplace
iteration:
  order: [j, i]
  domains:
    j: [0, Nj-1]
    i: [1, Ni-1]
kernels:
  laplace:
    declaration: laplace5(double n, double e, double s, double w, double c, double &o);
    inputs: |
      n : q?[j?-1][i?]
      e : q?[j?][i?+1]
      s : q?[j?+1][i?]
      w : q?[j?][i?-1]
      c : q?[j?][i?]
    outputs: |
      o : laplace(q?[j?][i?])
    body: "o = 0.25*(n + e + s + w) - c;"
globals:
  inputs: |
    double g_cell[j?][i?] => cell[j?][i?]
  outputs: |
    laplace(cell[j][i]) => double g_out[j][i]
"#;
    let prog = PlanSpec::deck_src(bad).compile().unwrap();
    let report = verify::check_program(&prog).unwrap();
    assert!(report.has_errors(), "expected input-underrun:\n{}", report.render());
    assert!(report.diagnostics.iter().any(|d| d.rule == "input-underrun"));
}

// ---------------------------------------------------------------------------
// Seeded-defect mutation tests: corrupt a correct compiled schedule the
// way a transformation bug would, and prove the matching rule fires.
// ---------------------------------------------------------------------------

/// Bump the innermost loop that directly invokes kernels by one
/// iteration — the classic peeling off-by-one.
fn bump_innermost_invoke_loop(nodes: &mut [Node]) -> bool {
    for n in nodes.iter_mut() {
        match n {
            Node::Loop(l) => {
                if bump_innermost_invoke_loop(&mut l.body) {
                    return true;
                }
                if l.body
                    .iter()
                    .any(|c| matches!(c, Node::Invoke(_) | Node::MemberStrip(_)))
                {
                    l.hi = l.hi.plus(1);
                    return true;
                }
            }
            Node::Parallel(p) => {
                if bump_innermost_invoke_loop(&mut p.body) {
                    return true;
                }
            }
            Node::Strip(s) => {
                if let Some(h) = &mut s.head {
                    if bump_innermost_invoke_loop(h) {
                        return true;
                    }
                }
                if bump_innermost_invoke_loop(&mut s.steady)
                    || bump_innermost_invoke_loop(&mut s.remainder)
                {
                    return true;
                }
            }
            Node::Guarded(g) => {
                for a in &mut g.arms {
                    if bump_innermost_invoke_loop(&mut a.body) {
                        return true;
                    }
                }
            }
            Node::Invoke(_) | Node::MemberStrip(_) => {}
        }
    }
    false
}

/// Reverse every node sequence in the tree (and guarded arm order) —
/// producers now run after their consumers.
fn reverse_bodies(nodes: &mut Vec<Node>) {
    nodes.reverse();
    for n in nodes.iter_mut() {
        match n {
            Node::Loop(l) => reverse_bodies(&mut l.body),
            Node::Parallel(p) => reverse_bodies(&mut p.body),
            Node::Strip(s) => {
                if let Some(h) = &mut s.head {
                    reverse_bodies(h);
                }
                reverse_bodies(&mut s.steady);
                reverse_bodies(&mut s.remainder);
            }
            Node::Guarded(g) => {
                g.arms.reverse();
                for a in &mut g.arms {
                    reverse_bodies(&mut a.body);
                }
            }
            Node::Invoke(_) | Node::MemberStrip(_) => {}
        }
    }
}

#[test]
fn mutation_loop_bound_off_by_one_is_out_of_bounds() {
    let mut prog = PlanSpec::app("laplace").vlen_resolved(Some(1)).compile().unwrap();
    let ext = probe(&prog);
    assert!(!verify::check_schedule_at(&prog, &ext, &[2]).unwrap().has_errors());
    let mut bumped = false;
    for np in &mut prog.sched.nests {
        if bump_innermost_invoke_loop(&mut np.body) {
            bumped = true;
            break;
        }
    }
    assert!(bumped, "laplace must lower to a plain invoking loop at vlen 1");
    let report = verify::check_schedule_at(&prog, &ext, &[2]).unwrap();
    assert!(
        report.diagnostics.iter().any(|d| d.rule == "bounds"),
        "expected a bounds finding:\n{}",
        report.render()
    );
}

#[test]
fn mutation_dropped_private_replica_is_a_race() {
    let mut prog = PlanSpec::app("cosmo").compile().unwrap();
    let mut dropped = false;
    for np in &mut prog.sched.nests {
        for n in &mut np.body {
            if let Node::Parallel(p) = n {
                if !p.private_storages.is_empty() {
                    p.private_storages.clear();
                    dropped = true;
                }
            }
        }
    }
    assert!(dropped, "cosmo must carry a parallel level with private storages");
    let ext = probe(&prog);
    let report = verify::check_schedule_at(&prog, &ext, &[2]).unwrap();
    assert!(
        report.diagnostics.iter().any(|d| d.rule == "race"),
        "expected a race finding:\n{}",
        report.render()
    );
}

#[test]
fn mutation_shrunk_window_is_a_stale_read() {
    let mut prog = PlanSpec::deck_src(CHAIN1D).compile().unwrap();
    let ext = probe(&prog);
    assert!(!verify::check_schedule_at(&prog, &ext, &[2]).unwrap().has_errors());
    // dbl(u)'s rolling window holds the producer's run-ahead (w = 3:
    // i-1, i, i+1 live at once); halving the allocation makes the i+1
    // write land on the cell the i-1 read still needs.
    let mut shrunk = false;
    for s in &mut prog.sp.storages {
        for sz in &mut s.sizes {
            if let DimSize::Window { alloc, .. } = sz {
                if *alloc >= 2 {
                    *alloc /= 2;
                    shrunk = true;
                }
            }
        }
    }
    assert!(shrunk, "chain1d must carry a windowed intermediate");
    let report = verify::check_schedule_at(&prog, &ext, &[2]).unwrap();
    assert!(
        report.diagnostics.iter().any(|d| d.rule == "stale-read"),
        "expected a stale-read finding:\n{}",
        report.render()
    );
}

#[test]
fn mutation_reordered_invokes_are_use_before_def() {
    let mut prog = PlanSpec::deck_src(CHAIN1D).compile().unwrap();
    // Run consumers before producers: the diff member now reads dbl(u)
    // cells its (pipelined, shifted) producer has not written yet.
    for np in &mut prog.sched.nests {
        reverse_bodies(&mut np.body);
    }
    let ext = probe(&prog);
    let report = verify::check_schedule_at(&prog, &ext, &[2]).unwrap();
    assert!(
        report.diagnostics.iter().any(|d| d.rule == "def-before-use"),
        "expected a def-before-use finding:\n{}",
        report.render()
    );
}
