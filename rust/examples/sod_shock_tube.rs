//! End-to-end driver (EXPERIMENTS.md §E2E): Hydro2D Sod shock tube through
//! the full stack — deck → fused schedule → generated C → `cc -O3` →
//! dlopen → dimensionally-split time loop — against the autovec baseline,
//! with conservation checks and the final density profile.
//!
//! ```sh
//! cargo run --release --example sod_shock_tube -- [size] [steps]
//! ```

fn main() -> Result<(), String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let size: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(128);
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    hfav::e2e::sod_demo(size, steps)
}
