//! COSMO diffusion (paper §5.3): compare autovec / STELLA-style / HFAV on
//! one diffusion application and show the contraction decisions.
//!
//! ```sh
//! cargo run --release --example cosmo_diffusion
//! ```

use hfav::apps::{cosmo, max_err, seeded, Variant};
use hfav::plan::PlanSpec;
use std::collections::BTreeMap;

fn main() -> Result<(), String> {
    let (nk, nj, ni) = (4usize, 66usize, 66usize);
    let u = seeded(nk * nj * ni, 11);
    let out_len = nk * (nj - 4) * (ni - 4);

    let mut out_ref = vec![0.0; out_len];
    cosmo::reference(&u, nk, nj, ni, &mut out_ref);
    let mut out_st = vec![0.0; out_len];
    cosmo::stella(&u, nk, nj, ni, &mut out_st);
    println!("STELLA vs autovec: max err {:.2e}", max_err(&out_ref, &out_st));

    let prog = PlanSpec::app("cosmo").compile()?;
    println!("\nHFAV contraction notes:");
    for n in &prog.sp.notes {
        println!("  {n}");
    }
    let module = hfav::codegen::native::build(&prog, &Default::default())?;
    let mut ext = BTreeMap::new();
    ext.insert("Nk".to_string(), nk as i64);
    ext.insert("Nj".to_string(), nj as i64);
    ext.insert("Ni".to_string(), ni as i64);
    let mut arrays = BTreeMap::new();
    arrays.insert("g_u".to_string(), u);
    arrays.insert("g_out".to_string(), vec![0.0; out_len]);
    module.run(&ext, &mut arrays)?;
    println!("HFAV (native) vs autovec: max err {:.2e}", max_err(&out_ref, &arrays["g_out"]));
    assert!(max_err(&out_ref, &arrays["g_out"]) < 1e-12);

    // Footprint at the paper's flavour of sizes.
    let mut big = BTreeMap::new();
    big.insert("Nk".to_string(), 8i64);
    big.insert("Nj".to_string(), 512i64);
    big.insert("Ni".to_string(), 512i64);
    let fused = prog.footprint_words(&big)?;
    let naive =
        PlanSpec::app("cosmo").variant(Variant::Autovec).compile()?.footprint_words(&big)?;
    println!("\nintermediate footprint @ 8x512x512: autovec={naive} words, hfav={fused} words");
    println!("cosmo_diffusion OK");
    Ok(())
}
