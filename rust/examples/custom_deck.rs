//! Authoring a new deck from scratch: a 1D heat-equation step chain
//! (double-application of a 3-point smoother) written as two rules, fused
//! by the engine into a single pipelined loop with rolling buffers —
//! the "bring your own kernels" workflow for downstream users.
//!
//! ```sh
//! cargo run --release --example custom_deck
//! ```

use hfav::exec::{self, registry::Registry, ExecOptions};
use hfav::plan::{compile_src, CompileOptions};
use std::collections::BTreeMap;

const DECK: &str = r#"
name: heat2x
iteration:
  order: [i]
  domains:
    i: [2, N-2]
kernels:
  smooth1:
    declaration: smooth1(double l, double c, double r, double &o);
    inputs: |
      l : u?[i?-1]
      c : u?[i?]
      r : u?[i?+1]
    outputs: |
      o : s1(u?[i?])
    body: "o = 0.25*l + 0.5*c + 0.25*r;"
  smooth2:
    declaration: smooth2(double l, double c, double r, double &o);
    inputs: |
      l : s1(u[i?-1])
      c : s1(u[i?])
      r : s1(u[i?+1])
    outputs: |
      o : s2(u[i?])
    body: "o = 0.25*l + 0.5*c + 0.25*r;"
globals:
  inputs: |
    double g_u[i?] => u[i?]
  outputs: |
    s2(u[i]) => double g_o[i]
"#;

fn main() -> Result<(), String> {
    let prog = compile_src(DECK, CompileOptions::default())?;
    println!("schedule:\n{}", prog.schedule_text());
    println!("notes:");
    for n in &prog.sp.notes {
        println!("  {n}");
    }
    // s1 contracts to a 3-slot rolling window; the two smoothers fuse into
    // one pipelined i-loop (smooth1 runs one iteration ahead).
    let s1 = prog.df.var("s1(u)").unwrap().id;
    println!("s1 storage: {:?}", prog.sp.storage_of(s1).sizes);

    let mut reg = Registry::new();
    let smooth = |i: &[f64], o: &mut [f64]| o[0] = 0.25 * i[0] + 0.5 * i[1] + 0.25 * i[2];
    reg.register("smooth1", smooth);
    reg.register("smooth2", smooth);

    let n = 32usize;
    let mut ext = BTreeMap::new();
    ext.insert("N".to_string(), n as i64);
    let u: Vec<f64> = (0..n).map(|i| if i == n / 2 { 1.0 } else { 0.0 }).collect();
    let mut inputs = BTreeMap::new();
    inputs.insert("g_u".to_string(), u.clone());
    let out = exec::run(&prog, &reg, &ext, &inputs, ExecOptions::default())?;

    // reference: two explicit passes
    let mut s1v = vec![0.0; n];
    for i in 1..n - 1 {
        s1v[i] = 0.25 * u[i - 1] + 0.5 * u[i] + 0.25 * u[i + 1];
    }
    let mut want = vec![0.0; n - 4];
    for i in 2..n - 2 {
        want[i - 2] = 0.25 * s1v[i - 1] + 0.5 * s1v[i] + 0.25 * s1v[i + 1];
    }
    let err = hfav::apps::max_err(&out["g_o"], &want);
    println!("fused vs two-pass reference: max err {err:.3e}");
    assert!(err < 1e-14);
    println!("custom_deck OK");
    Ok(())
}
