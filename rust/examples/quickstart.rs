//! Quickstart: drive the whole HFAV pipeline on the paper's running
//! example (the 5-point Laplace stencil, Listing 1 / Fig. 10).
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use hfav::apps::{laplace, seeded};
use hfav::exec::{self, ExecOptions};
use hfav::plan::{compile_src, CompileOptions};
use std::collections::BTreeMap;

fn main() -> Result<(), String> {
    // 1. Compile the declarative deck: inference → fusion → contraction.
    let prog = compile_src(laplace::DECK, CompileOptions::default())?;
    println!("=== schedule (paper Fig. 6 analogue) ===");
    println!("{}", prog.schedule_text());

    println!("=== storage analysis ===");
    for note in &prog.sp.notes {
        println!("  {note}");
    }
    for s in &prog.sp.storages {
        println!("  {:<16} {:?}", s.name, s.sizes);
    }

    // 2. Emit C99 (what the paper's tool ships to icc).
    let c = hfav::codegen::c99::emit(&prog)?;
    println!("\n=== generated C99 (first 30 lines) ===");
    for line in c.lines().take(30) {
        println!("{line}");
    }

    // 3. Execute the schedule in-process and validate against a plain
    //    hand-written reference.
    let (nj, ni) = (64usize, 64usize);
    let mut extents = BTreeMap::new();
    extents.insert("Nj".to_string(), nj as i64);
    extents.insert("Ni".to_string(), ni as i64);
    let u = seeded(nj * ni, 1);
    let mut inputs = BTreeMap::new();
    inputs.insert("g_cell".to_string(), u.clone());
    let out = exec::run(&prog, &laplace::registry(), &extents, &inputs, ExecOptions::default())?;
    let want = laplace::reference(&u, nj, ni);
    let err = hfav::apps::max_err(&out["g_out"], &want);
    println!("\nexecutor vs reference: max err {err:.3e}");
    assert!(err < 1e-12);

    // 4. Compile the generated C with the system compiler and run it.
    let module = hfav::codegen::native::build(&prog, &Default::default())?;
    let mut arrays = BTreeMap::new();
    arrays.insert("g_cell".to_string(), u);
    arrays.insert("g_out".to_string(), vec![0.0; (nj - 2) * (ni - 2)]);
    module.run(&extents, &mut arrays)?;
    let err = hfav::apps::max_err(&arrays["g_out"], &want);
    println!("native (cc -O3) vs reference: max err {err:.3e}");
    assert!(err < 1e-12);
    println!("\nquickstart OK");
    Ok(())
}
