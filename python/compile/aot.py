"""AOT lowering: every app × variant → HLO *text* artifacts + manifest.

HLO text (not `.serialize()`): jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids that the xla crate's xla_extension 0.5.1 rejects; the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage: python -m compile.aot --out-dir ../artifacts [--sizes nj=512,...]
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: str, sizes: dict) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    for name, (fn, arg_builder) in model.VARIANTS.items():
        args = arg_builder(sizes)
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        in_sig = ",".join(
            "x".join(str(d) for d in a.shape) if a.shape else "scalar" for a in args
        )
        outs = jax.eval_shape(fn, *args)
        out_list = jax.tree_util.tree_leaves(outs)
        out_sig = ",".join("x".join(str(d) for d in o.shape) for o in out_list)
        manifest.append(f"{name}|{fname}|{in_sig}|{out_sig}")
        print(f"lowered {name}: in [{in_sig}] out [{out_sig}] -> {fname}")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")


def parse_sizes(spec: str) -> dict:
    sizes = dict(model.DEFAULT_SIZES)
    if spec:
        for kv in spec.split(","):
            k, v = kv.split("=")
            sizes[k.strip()] = int(v)
    return sizes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(compat) ignored if --out-dir given")
    ap.add_argument("--sizes", default="")
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out and out_dir == "../artifacts":
        out_dir = os.path.dirname(args.out) or "."
    lower_all(out_dir, parse_sizes(args.sizes))


if __name__ == "__main__":
    main()
