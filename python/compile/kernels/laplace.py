"""Fused 5-point Laplace as a Pallas kernel (Layer 1).

The HFAV schedule — one sweep over `j` with a 3-row working set — maps to
a Pallas grid over output rows: each grid step holds the three contributing
input rows in VMEM and emits one output row. On a real TPU the pipelined
grid gives exactly the paper's rolling 3-row buffer (adjacent steps re-use
two of the three rows from VMEM); `interpret=True` is required for CPU
execution (see DESIGN.md §Hardware-Adaptation).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(n_ref, c_ref, s_ref, o_ref):
    n = n_ref[0, :]
    c = c_ref[0, :]
    s = s_ref[0, :]
    # east/west are shifts within the row held in VMEM.
    o_ref[0, :] = 0.25 * (n[1:-1] + c[2:] + s[1:-1] + c[:-2]) - c[1:-1]


def laplace_fused(u):
    """u: (nj, ni) -> (nj-2, ni-2), fused single sweep."""
    nj, ni = u.shape
    return pl.pallas_call(
        _kernel,
        grid=(nj - 2,),
        in_specs=[
            pl.BlockSpec((1, ni), lambda j: (j, 0)),      # north row (j)
            pl.BlockSpec((1, ni), lambda j: (j + 1, 0)),  # center row (j+1)
            pl.BlockSpec((1, ni), lambda j: (j + 2, 0)),  # south row (j+2)
        ],
        out_specs=pl.BlockSpec((1, ni - 2), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((nj - 2, ni - 2), u.dtype),
        interpret=True,
    )(u, u, u)
