"""Fused Hydro2D sweep as a Pallas kernel (Layer 1).

The paper's eight sweep kernels fuse into one kernel invocation per row:
all ~33 intermediate arrays become row-resident VMEM temporaries and the
conservative fields cross HBM exactly once per sweep — the TPU rendering
of the paper's `O(31·Ni·Nj)` → `O(4·Ni·Nj + 112)` contraction (§5.4);
rolling scalar windows become VMEM row vectors, with the VPU vectorizing
over `i` where the paper's AVX-512 vectorized the rotated buffers.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _kernel(rho_ref, rhou_ref, rhov_ref, e_ref, dtdx_ref, nr_ref, nu_ref, nv_ref, ne_ref):
    rho = rho_ref[0, :][None, :]
    rhou = rhou_ref[0, :][None, :]
    rhov = rhov_ref[0, :][None, :]
    e = e_ref[0, :][None, :]
    dtdx = dtdx_ref[0, 0]
    nrho, nrhou, nrhov, ne = ref.hydro_sweep(rho, rhou, rhov, e, dtdx)
    nr_ref[0, :] = nrho[0, :]
    nu_ref[0, :] = nrhou[0, :]
    nv_ref[0, :] = nrhov[0, :]
    ne_ref[0, :] = ne[0, :]


def hydro_sweep_fused(rho, rhou, rhov, E, dtdx):
    """Padded (rows, n+4) fields + scalar dtdx -> four (rows, n) updates.

    The whole eight-stage pipeline runs per row inside one Pallas kernel;
    jnp ops inside the kernel lower to VPU vector ops over the row held in
    VMEM (the paper's fused steady-state loop).
    """
    rows, w = rho.shape
    n = w - 4
    dtdx_arr = jnp.asarray(dtdx, dtype=rho.dtype).reshape(1, 1)
    row = lambda j: (j, 0)  # noqa: E731
    out = pl.pallas_call(
        _kernel,
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, w), row),
            pl.BlockSpec((1, w), row),
            pl.BlockSpec((1, w), row),
            pl.BlockSpec((1, w), row),
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n), row),
            pl.BlockSpec((1, n), row),
            pl.BlockSpec((1, n), row),
            pl.BlockSpec((1, n), row),
        ],
        out_shape=[jax.ShapeDtypeStruct((rows, n), rho.dtype) for _ in range(4)],
        interpret=True,
    )(rho, rhou, rhov, E, dtdx_arr)
    return tuple(out)
