"""Pure-jnp reference oracles — the *unfused* shapes of the paper's codes.

Each function is the "autovec" form: one jnp pass per kernel with all
intermediates materialized. These are (a) the correctness oracles for the
Pallas fused kernels and (b) the unfused AOT artifacts the Rust runtime
benchmarks against.
"""

import jax.numpy as jnp

GAMMA = 1.4
ALPHA = 0.1


# ---------------------------------------------------------------------------
# 5-point Laplace (paper Listing 1)
# ---------------------------------------------------------------------------
def laplace(u):
    """u: (nj, ni) -> interior Laplace, (nj-2, ni-2)."""
    n = u[:-2, 1:-1]
    e = u[1:-1, 2:]
    s = u[2:, 1:-1]
    w = u[1:-1, :-2]
    c = u[1:-1, 1:-1]
    return 0.25 * (n + e + s + w) - c


# ---------------------------------------------------------------------------
# normalization example (paper §3, §5.2) — five separate sweeps
# ---------------------------------------------------------------------------
def normalize(q):
    """q: (nj, ni+1) -> normalized flux differences, (nj, ni)."""
    f = q[:, 1:] - q[:, :-1]            # sweep 1: flux
    acc = jnp.zeros(q.shape[0])          # sweep 2: init
    acc = acc + jnp.sum(f * f, axis=1)   # sweep 3: accumulate
    r = 1.0 / jnp.sqrt(acc + 1e-30)      # sweep 4: root
    return f * r[:, None]                # sweep 5: normalize


# ---------------------------------------------------------------------------
# COSMO fourth-order diffusion micro-kernels (paper §5.3)
# ---------------------------------------------------------------------------
def _limit(f, du):
    return jnp.where(f * du > 0.0, 0.0, f)


def cosmo(u):
    """u: (nk, nj, ni) -> diffused interior, (nk, nj-4, ni-4)."""
    lap = (
        u[:, :-2, 1:-1] + u[:, 1:-1, 2:] + u[:, 2:, 1:-1] + u[:, 1:-1, :-2]
        - 4.0 * u[:, 1:-1, 1:-1]
    )
    uc = u[:, 1:-1, 1:-1]
    fx = _limit(lap[:, :, 1:] - lap[:, :, :-1], uc[:, :, 1:] - uc[:, :, :-1])
    fy = _limit(lap[:, 1:, :] - lap[:, :-1, :], uc[:, 1:, :] - uc[:, :-1, :])
    out = (
        u[:, 2:-2, 2:-2]
        - ALPHA
        * (
            fx[:, 1:-1, 1:] - fx[:, 1:-1, :-1]
            + fy[:, 1:, 1:-1] - fy[:, :-1, 1:-1]
        )
    )
    return out


# ---------------------------------------------------------------------------
# Hydro2D sweep (paper §5.4) — eight separate vectorized passes
# ---------------------------------------------------------------------------
def _slope(qm, qc, qp):
    dl = qc - qm
    dg = qp - qc
    dc = 0.5 * (dl + dg)
    s = jnp.where(dc >= 0.0, 1.0, -1.0)
    lim = jnp.where(dl * dg <= 0.0, 0.0, 2.0 * jnp.minimum(jnp.abs(dl), jnp.abs(dg)))
    return s * jnp.minimum(lim, jnp.abs(dc))


def _riemann(rl, ul, vl, pl, rr, ur, vr, pr):
    cl = jnp.sqrt(GAMMA * pl / rl)
    cr = jnp.sqrt(GAMMA * pr / rr)
    pst = jnp.maximum(
        1e-10, 0.5 * (pl + pr) - 0.125 * (ur - ul) * (rl + rr) * (cl + cr)
    )
    for _ in range(8):
        al, bl = 0.8333333333333333 / rl, 0.16666666666666666 * pl
        ar, br = 0.8333333333333333 / rr, 0.16666666666666666 * pr
        sl = jnp.sqrt(al / (pst + bl))
        sr = jnp.sqrt(ar / (pst + br))
        fl = (pst - pl) * sl
        fr = (pst - pr) * sr
        dl = sl * (1.0 - (pst - pl) / (2.0 * (pst + bl)))
        dr = sr * (1.0 - (pst - pr) / (2.0 * (pst + br)))
        pst = jnp.maximum(1e-10, pst - (fl + fr + (ur - ul)) / (dl + dr))
    sl0 = jnp.sqrt((0.8333333333333333 / rl) / (pst + 0.16666666666666666 * pl))
    sr0 = jnp.sqrt((0.8333333333333333 / rr) / (pst + 0.16666666666666666 * pr))
    ustar = 0.5 * (ul + ur) + 0.5 * ((pst - pr) * sr0 - (pst - pl) * sl0)
    left = ustar >= 0.0
    sgn = jnp.where(left, 1.0, -1.0)
    r0 = jnp.where(left, rl, rr)
    u0 = jnp.where(left, ul, ur)
    p0 = jnp.where(left, pl, pr)
    v0 = jnp.where(left, vl, vr)
    c0 = jnp.sqrt(GAMMA * p0 / r0)
    q = pst / p0
    s_spd = u0 - sgn * c0 * jnp.sqrt(0.8571428571428571 * q + 0.14285714285714285)
    shock_out = sgn * s_spd >= 0.0
    ro_sh = jnp.where(
        shock_out,
        r0,
        r0 * ((q + 0.16666666666666666) / (0.16666666666666666 * q + 1.0)),
    )
    uo_sh = jnp.where(shock_out, u0, ustar)
    po_sh = jnp.where(shock_out, p0, pst)
    cst = c0 * q ** 0.14285714285714285
    sh_spd = u0 - sgn * c0
    st_spd = ustar - sgn * cst
    uo_fan = 0.8333333333333333 * (sgn * c0 + 0.2 * u0)
    cf = jnp.maximum(sgn * uo_fan, 1e-12)
    ro_fan = r0 * (cf / c0) ** 5.0
    po_fan = p0 * (cf / c0) ** 7.0
    ro_rf = jnp.where(
        sgn * sh_spd >= 0.0,
        r0,
        jnp.where(sgn * st_spd <= 0.0, r0 * q ** 0.7142857142857143, ro_fan),
    )
    uo_rf = jnp.where(
        sgn * sh_spd >= 0.0, u0, jnp.where(sgn * st_spd <= 0.0, ustar, uo_fan)
    )
    po_rf = jnp.where(
        sgn * sh_spd >= 0.0, p0, jnp.where(sgn * st_spd <= 0.0, pst, po_fan)
    )
    shock = pst > p0
    ro = jnp.where(shock, ro_sh, ro_rf)
    uo = jnp.where(shock, uo_sh, uo_rf)
    po = jnp.where(shock, po_sh, po_rf)
    return ro, uo, v0, po


def hydro_sweep(rho, rhou, rhov, E, dtdx):
    """One dimensionally-split sweep over padded rows.

    Inputs: (rows, n+4) padded conservative fields; returns (rows, n)
    updated interior. Mirrors `apps::hydro2d::solver::RefSweeper`.
    """
    r = rho
    u = rhou / rho
    v = rhov / rho
    eint = E / rho - 0.5 * (u * u + v * v)
    p = jnp.maximum(0.4 * r * eint, 1e-10)
    dr = _slope(r[:, :-2], r[:, 1:-1], r[:, 2:])
    du = _slope(u[:, :-2], u[:, 1:-1], u[:, 2:])
    dv = _slope(v[:, :-2], v[:, 1:-1], v[:, 2:])
    dp = _slope(p[:, :-2], p[:, 1:-1], p[:, 2:])
    rc, uc, vc, pc = r[:, 1:-1], u[:, 1:-1], v[:, 1:-1], p[:, 1:-1]
    h = 0.5 * dtdx
    r2 = jnp.maximum(rc - h * (uc * dr + rc * du), 1e-10)
    u2 = uc - h * (uc * du + dp / rc)
    v2 = vc - h * (uc * dv)
    p2 = jnp.maximum(pc - h * (GAMMA * pc * du + uc * dp), 1e-10)
    clamp = lambda x: jnp.maximum(x, 1e-10)  # noqa: E731
    trm, tum = clamp(r2 - 0.5 * dr), u2 - 0.5 * du
    tvm, tpm = v2 - 0.5 * dv, clamp(p2 - 0.5 * dp)
    trp, tup = clamp(r2 + 0.5 * dr), u2 + 0.5 * du
    tvp, tpp = v2 + 0.5 * dv, clamp(p2 + 0.5 * dp)
    gr, gu, gv, gp = _riemann(
        trp[:, :-1], tup[:, :-1], tvp[:, :-1], tpp[:, :-1],
        trm[:, 1:], tum[:, 1:], tvm[:, 1:], tpm[:, 1:],
    )
    e_g = gp / (GAMMA - 1.0) + 0.5 * gr * (gu * gu + gv * gv)
    frho = gr * gu
    frhou = gr * gu * gu + gp
    frhov = gr * gu * gv
    fE = gu * (e_g + gp)
    nrho = rho[:, 2:-2] + dtdx * (frho[:, :-1] - frho[:, 1:])
    nrhou = rhou[:, 2:-2] + dtdx * (frhou[:, :-1] - frhou[:, 1:])
    nrhov = rhov[:, 2:-2] + dtdx * (frhov[:, :-1] - frhov[:, 1:])
    nE = E[:, 2:-2] + dtdx * (fE[:, :-1] - fE[:, 1:])
    return nrho, nrhou, nrhov, nE
