"""Fused COSMO fourth-order diffusion as a Pallas kernel (Layer 1).

All four kernels (ulapstage, flux_x, flux_y, ustage) fuse into one grid
step per (k, output-row): the five contributing input rows stream through
VMEM and the Laplacian/flux intermediates never reach HBM — the TPU
rendering of the paper's rolling buffers (§5.3). On real hardware the
sequential `j` grid dimension makes Mosaic's pipelining hold the
overlapping rows in VMEM across steps, which is precisely the 3-row
Laplacian window; under `interpret=True` we validate the numerics.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ALPHA = 0.1


def _limit(f, du):
    return jnp.where(f * du > 0.0, 0.0, f)


def _kernel(r0, r1, r2, r3, r4, o_ref):
    # rows j .. j+4 of u (output row corresponds to u row j+2).
    u0 = r0[0, 0, :]
    u1 = r1[0, 0, :]
    u2 = r2[0, 0, :]
    u3 = r3[0, 0, :]
    u4 = r4[0, 0, :]

    def lap(um, uc, up):
        return um[1:-1] + uc[2:] + up[1:-1] + uc[:-2] - 4.0 * uc[1:-1]

    l1 = lap(u0, u1, u2)  # lap at u-row j+1
    l2 = lap(u1, u2, u3)  # lap at u-row j+2
    l3 = lap(u2, u3, u4)  # lap at u-row j+3
    c1, c2, c3 = u1[1:-1], u2[1:-1], u3[1:-1]
    fx = _limit(l2[1:] - l2[:-1], c2[1:] - c2[:-1])
    fy_lo = _limit(l2 - l1, c2 - c1)  # flux between rows j+1, j+2
    fy_hi = _limit(l3 - l2, c3 - c2)  # flux between rows j+2, j+3
    o_ref[0, 0, :] = u2[2:-2] - ALPHA * (
        fx[1:] - fx[:-1] + fy_hi[1:-1] - fy_lo[1:-1]
    )


def cosmo_fused(u):
    """u: (nk, nj, ni) -> (nk, nj-4, ni-4), single fused sweep."""
    nk, nj, ni = u.shape
    specs = [
        pl.BlockSpec((1, 1, ni), lambda k, j, dj=dj: (k, j + dj, 0)) for dj in range(5)
    ]
    return pl.pallas_call(
        _kernel,
        grid=(nk, nj - 4),
        in_specs=specs,
        out_specs=pl.BlockSpec((1, 1, ni - 4), lambda k, j: (k, j, 0)),
        out_shape=jax.ShapeDtypeStruct((nk, nj - 4, ni - 4), u.dtype),
        interpret=True,
    )(u, u, u, u, u)
