"""Fused normalization example as a Pallas kernel (Layer 1).

The paper's five sweeps (flux, init, accumulate, root, normalize) fuse into
a single per-row pipeline: the flux row, the accumulator and the
reciprocal norm all live in VMEM and HBM is touched once for the input row
and once for the output row. The reduction→broadcast split (§5.2) is
internal to the row here: the row *is* the reduction scope, so the two
fused nests become two VMEM-resident stages of one kernel.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, o_ref):
    q = q_ref[0, :]
    f = q[1:] - q[:-1]          # nest 1: flux + accumulate (+ root)
    acc = jnp.sum(f * f)
    r = 1.0 / jnp.sqrt(acc + 1e-30)
    o_ref[0, :] = f * r          # nest 2: normalize broadcast


def normalize_fused(q):
    """q: (nj, ni+1) -> (nj, ni), one fused pass."""
    nj, w = q.shape
    ni = w - 1
    return pl.pallas_call(
        _kernel,
        grid=(nj,),
        in_specs=[pl.BlockSpec((1, w), lambda j: (j, 0))],
        out_specs=pl.BlockSpec((1, ni), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((nj, ni), q.dtype),
        interpret=True,
    )(q)
