"""Layer 2: jit-able step functions for every app × variant.

`fused` variants call the Layer-1 Pallas kernels; `unfused` variants are
the materializing jnp pipelines from `kernels.ref`. Both lower to HLO text
via `aot.py` and run from the Rust PJRT runtime — Python never sits on the
request path.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .kernels import ref  # noqa: E402
from .kernels.cosmo import cosmo_fused  # noqa: E402
from .kernels.hydro import hydro_sweep_fused  # noqa: E402
from .kernels.laplace import laplace_fused  # noqa: E402
from .kernels.normalization import normalize_fused  # noqa: E402


def laplace_unfused(u):
    return (ref.laplace(u),)


def laplace_fused_fn(u):
    return (laplace_fused(u),)


def normalize_unfused(q):
    return (ref.normalize(q),)


def normalize_fused_fn(q):
    return (normalize_fused(q),)


def cosmo_unfused(u):
    return (ref.cosmo(u),)


def cosmo_fused_fn(u):
    return (cosmo_fused(u),)


def hydro_unfused(rho, rhou, rhov, E, dtdx):
    return ref.hydro_sweep(rho, rhou, rhov, E, dtdx[0, 0])


def hydro_fused_fn(rho, rhou, rhov, E, dtdx):
    return hydro_sweep_fused(rho, rhou, rhov, E, dtdx[0, 0])


def f64(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float64)


#: name -> (callable, example-arg builder over a size parameter table)
VARIANTS = {
    "laplace_unfused": (laplace_unfused, lambda s: [f64(s["nj"], s["ni"])]),
    "laplace_fused": (laplace_fused_fn, lambda s: [f64(s["nj"], s["ni"])]),
    "normalize_unfused": (normalize_unfused, lambda s: [f64(s["nj"], s["ni"] + 1)]),
    "normalize_fused": (normalize_fused_fn, lambda s: [f64(s["nj"], s["ni"] + 1)]),
    "cosmo_unfused": (cosmo_unfused, lambda s: [f64(s["nk"], s["nj"], s["ni"])]),
    "cosmo_fused": (cosmo_fused_fn, lambda s: [f64(s["nk"], s["nj"], s["ni"])]),
    "hydro_unfused": (
        hydro_unfused,
        lambda s: [f64(s["rows"], s["n"] + 4)] * 4 + [f64(1, 1)],
    ),
    "hydro_fused": (
        hydro_fused_fn,
        lambda s: [f64(s["rows"], s["n"] + 4)] * 4 + [f64(1, 1)],
    ),
}

#: default AOT shapes (the Rust coordinator's executable cache keys on these)
DEFAULT_SIZES = {
    "nj": 512,
    "ni": 512,
    "nk": 8,
    "rows": 64,
    "n": 512,
}
