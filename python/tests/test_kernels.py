"""Layer-1 correctness: Pallas fused kernels vs pure-jnp oracles.

This is the core correctness signal for the compile path: the fused
kernels must agree with the unfused reference pipelines to float64
round-off across a hypothesis sweep of shapes.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

import sys, os  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile.kernels import ref  # noqa: E402
from compile.kernels.cosmo import cosmo_fused  # noqa: E402
from compile.kernels.hydro import hydro_sweep_fused  # noqa: E402
from compile.kernels.laplace import laplace_fused  # noqa: E402
from compile.kernels.normalization import normalize_fused  # noqa: E402


def rng_fill(shape, seed):
    return jnp.asarray(
        np.random.default_rng(seed).uniform(0.1, 1.0, size=shape), dtype=jnp.float64
    )


# ---------------------------------------------------------------------------
# Laplace
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(
    nj=st.integers(min_value=3, max_value=40),
    ni=st.integers(min_value=3, max_value=40),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_laplace_fused_matches_ref(nj, ni, seed):
    u = rng_fill((nj, ni), seed)
    np.testing.assert_allclose(laplace_fused(u), ref.laplace(u), rtol=1e-12, atol=1e-12)


def test_laplace_against_numpy():
    u = np.random.default_rng(0).uniform(size=(7, 9))
    got = np.asarray(ref.laplace(jnp.asarray(u)))
    for j in range(1, 6):
        for i in range(1, 8):
            want = 0.25 * (u[j - 1, i] + u[j, i + 1] + u[j + 1, i] + u[j, i - 1]) - u[j, i]
            assert abs(got[j - 1, i - 1] - want) < 1e-12


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(
    nj=st.integers(min_value=1, max_value=24),
    ni=st.integers(min_value=1, max_value=50),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_normalize_fused_matches_ref(nj, ni, seed):
    q = rng_fill((nj, ni + 1), seed)
    np.testing.assert_allclose(
        normalize_fused(q), ref.normalize(q), rtol=1e-12, atol=1e-12
    )


def test_normalize_rows_unit_norm():
    q = rng_fill((4, 33), 7)
    out = np.asarray(normalize_fused(q))
    norms = np.sqrt((out * out).sum(axis=1))
    np.testing.assert_allclose(norms, 1.0, rtol=1e-9)


# ---------------------------------------------------------------------------
# COSMO diffusion
# ---------------------------------------------------------------------------
@settings(max_examples=6, deadline=None)
@given(
    nk=st.integers(min_value=1, max_value=4),
    nj=st.integers(min_value=5, max_value=20),
    ni=st.integers(min_value=5, max_value=20),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_cosmo_fused_matches_ref(nk, nj, ni, seed):
    u = rng_fill((nk, nj, ni), seed)
    np.testing.assert_allclose(cosmo_fused(u), ref.cosmo(u), rtol=1e-12, atol=1e-12)


def test_cosmo_constant_field_is_fixed_point():
    u = jnp.ones((2, 8, 8), dtype=jnp.float64) * 3.5
    out = np.asarray(cosmo_fused(u))
    np.testing.assert_allclose(out, 3.5, rtol=0, atol=1e-12)


# ---------------------------------------------------------------------------
# Hydro2D sweep
# ---------------------------------------------------------------------------
def sod_padded(rows, n):
    rho = np.full((rows, n + 4), 0.125)
    rho[:, : (n + 4) // 2] = 1.0
    e = np.full((rows, n + 4), 0.1 / 0.4)
    e[:, : (n + 4) // 2] = 1.0 / 0.4
    z = np.zeros((rows, n + 4))
    return (
        jnp.asarray(rho),
        jnp.asarray(z),
        jnp.asarray(z),
        jnp.asarray(e),
    )


@settings(max_examples=4, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=4),
    n=st.integers(min_value=8, max_value=48),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hydro_fused_matches_ref_random(rows, n, seed):
    g = np.random.default_rng(seed)
    rho = jnp.asarray(g.uniform(0.5, 1.5, size=(rows, n + 4)))
    rhou = jnp.asarray(g.uniform(-0.1, 0.1, size=(rows, n + 4)))
    rhov = jnp.asarray(g.uniform(-0.1, 0.1, size=(rows, n + 4)))
    E = jnp.asarray(g.uniform(1.0, 2.0, size=(rows, n + 4)))
    dtdx = 0.05
    got = hydro_sweep_fused(rho, rhou, rhov, E, dtdx)
    want = ref.hydro_sweep(rho, rhou, rhov, E, dtdx)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-12)


def test_hydro_sod_mass_flux_sane():
    rho, rhou, rhov, E = sod_padded(2, 64)
    nrho, _, _, nE = ref.hydro_sweep(rho, rhou, rhov, E, 0.1)[0::3][0], *[None] * 2, None  # noqa
    # simpler: recompute
    out = ref.hydro_sweep(rho, rhou, rhov, E, 0.1)
    nrho = np.asarray(out[0])
    assert np.all(nrho > 0.0)
    assert np.all(nrho <= 1.0 + 1e-12)
